"""Production training launcher: ``python -m repro.launch.train --arch <id>``.

On this CPU container it runs the reduced config on simulated nodes; on a real
TPU slice the same entry point builds the production mesh and shards the
decentralized state per DESIGN.md §4.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import (ALGORITHMS, DataConfig, DistConfig,
                           OptimizerConfig, TrainConfig, get_model_config,
                           list_archs)
from repro.train import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(list_archs()))
    ap.add_argument("--algorithm", default="gossip_pga",
                    choices=list(ALGORITHMS),
                    help="registered algorithm (repro.core.algo), incl. "
                         "gt_pga: gradient tracking + periodic global "
                         "averaging for non-IID data")
    ap.add_argument("--topology", default="one_peer_exp")
    ap.add_argument("--H", type=int, default=6)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--comm-backend", default="reference",
                    choices=("reference", "pallas"),
                    help="mixing implementation (DESIGN.md §2.1): roll-based "
                         "reference or fused Pallas kernels")
    ap.add_argument("--comm-shard-mode", default="auto",
                    choices=("auto", "stacked", "sharded"),
                    help="pallas backend under a mesh-sharded node axis: "
                         "auto-detect, force the local stacked kernels, or "
                         "require the shard_map path (DESIGN.md §2.1)")
    ap.add_argument("--leaf-threshold", type=int, default=262_144,
                    help="per-node elements at which a parameter leaf gets "
                         "its own pallas dispatch (skips the concat staging "
                         "buffer)")
    ap.add_argument("--comm-compression", default="none",
                    choices=("none", "identity", "int8", "fp8", "topk",
                             "randk"),
                    help="wire compressor for the communication round "
                         "(repro.compress, DESIGN.md §2.3); identity is "
                         "bit-identical to none")
    ap.add_argument("--comm-compression-k", type=int, default=32,
                    help="elements kept per node per leaf for topk/randk")
    ap.add_argument("--comm-global-compression", default="none",
                    choices=("none", "identity", "int8", "fp8"),
                    help="compressed collective for the global/pod-"
                         "averaging phases (DESIGN.md §2.3 Compressed "
                         "collectives); identity is bit-identical to none")
    ap.add_argument("--error-feedback", action="store_true",
                    help="per-node error-feedback memory: compression "
                         "error is fed back next round instead of dropped")
    ap.add_argument("--comm-overlap", action="store_true",
                    help="pipelined gossip (DESIGN.md §2.6): the mixing "
                         "round of step t overlaps the compute of step "
                         "t+1 via a one-step-stale double buffer; global/"
                         "PGA rounds stay synchronous")
    ap.add_argument("--push-sum", action="store_true",
                    help="push-sum gossip (DESIGN.md §2.5): column-"
                         "stochastic directed mixing with a per-node weight "
                         "scalar, de-biased at read time — required for "
                         "directed topologies and fault injection")
    ap.add_argument("--fault-drop", default="",
                    help="drop events as 'step:id,id[;step:id,...]', e.g. "
                         "'40:3,5;90:0' drops nodes 3,5 at step 40 and "
                         "node 0 at step 90 (requires --push-sum)")
    ap.add_argument("--fault-rejoin", default="",
                    help="rejoin events, same syntax as --fault-drop")
    ap.add_argument("--fault-resample", default="none",
                    choices=("none", "hop", "peer"),
                    help="re-draw the gossip wiring each step: 'hop' "
                         "resamples one shared power-of-two hop, 'peer' "
                         "gives every node its own draw")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the deterministic per-step fault/"
                         "resample RNG (counter-based; resume-stable)")
    ap.add_argument("--full-config", action="store_true",
                    help="full published dims (TPU-scale; default reduced)")
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--telemetry-dir", default="",
                    help="write the structured telemetry stream "
                         "(DESIGN.md §2.7) to <dir>/telemetry.jsonl: step "
                         "records, per-round comm byte/latency meters, "
                         "fault + checkpoint events")
    ap.add_argument("--trace", default="",
                    help="save a Chrome-trace-event timeline of the run's "
                         "host spans to this path (load in Perfetto / "
                         "chrome://tracing)")
    ap.add_argument("--trace-fence", action="store_true",
                    help="block_until_ready at span exits so spans measure "
                         "device time instead of async dispatch time "
                         "(serializes the pipeline it measures)")
    args = ap.parse_args()

    cfg = get_model_config(args.arch, reduced=not args.full_config)
    tcfg = TrainConfig(
        model=cfg,
        dist=DistConfig(algorithm=args.algorithm, topology=args.topology,
                        H=args.H, comm_backend=args.comm_backend,
                        comm_shard_mode=args.comm_shard_mode,
                        pallas_leaf_threshold=args.leaf_threshold,
                        comm_compression=args.comm_compression,
                        comm_compression_k=args.comm_compression_k,
                        comm_global_compression=args.comm_global_compression,
                        comm_error_feedback=args.error_feedback,
                        comm_overlap=args.comm_overlap,
                        push_sum=args.push_sum),
        optimizer=OptimizerConfig(name=args.optimizer, lr=args.lr,
                                  schedule="warmup_cosine", warmup_steps=10,
                                  total_steps=args.steps),
        data=DataConfig(non_iid=not args.iid),
        global_batch=args.global_batch, seq_len=args.seq_len,
        steps=args.steps, log_every=max(args.steps // 10, 1))
    fault_schedule = None
    if args.fault_drop or args.fault_rejoin or args.fault_resample != "none":
        from repro.core.faults import FaultSchedule, parse_fault_events
        fault_schedule = FaultSchedule(
            n_nodes=args.nodes,
            drops=parse_fault_events(args.fault_drop),
            rejoins=parse_fault_events(args.fault_rejoin),
            resample=args.fault_resample,
            seed=args.fault_seed)
    telemetry = None
    if args.telemetry_dir or args.trace or args.trace_fence:
        import os
        from repro import obs
        sinks = [obs.RingSink(), obs.PrettySink()]
        if args.telemetry_dir:
            os.makedirs(args.telemetry_dir, exist_ok=True)
            sinks.insert(0, obs.JsonlSink(
                os.path.join(args.telemetry_dir, "telemetry.jsonl")))
        telemetry = obs.Telemetry(sinks=sinks, fence=args.trace_fence)
    tr = Trainer(tcfg, n_nodes=args.nodes, with_consensus=True,
                 fault_schedule=fault_schedule, telemetry=telemetry)
    state = tr.init_state(jax.random.PRNGKey(0))
    tr.run(state, steps=args.steps)
    if telemetry is not None:
        if args.trace:
            print("trace:", telemetry.tracer.save(args.trace))
        telemetry.close()


if __name__ == "__main__":
    main()
