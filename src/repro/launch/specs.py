"""input_specs: ShapeDtypeStruct stand-ins + shardings for every
(architecture × input shape × mesh) combination — weak-type-correct,
shardable, zero allocation.

Three step kinds:
  train   — ``train_step(state, batch, lr)`` (TrainState via eval_shape)
  prefill — ``forward(params, batch)`` full-sequence with cache out
  decode  — ``serve_step(params, caches, tokens, pos)`` ONE new token against
            a full ``seq_len`` cache (the brief's decode semantics)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (DataConfig, DistConfig, InputShape,
                                ModelConfig, OptimizerConfig, TrainConfig)
from repro.launch.mesh import n_gossip_nodes
from repro.models import sharding as shd
from repro.models.model import Model, make_model
from repro.optim import make_optimizer
from repro.train.state import (TrainState, stack_for_nodes, stacked_axes,
                               state_axes)

PyTree = Any
def _IS_AXES(x):
    return isinstance(x, tuple)


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def _shardings(axes_tree: PyTree, mode: str, mesh: Mesh,
               sds_tree: Optional[PyTree] = None) -> PyTree:
    """Shape-aware sharding resolution (skips non-divisible dims)."""
    if sds_tree is None:
        return jax.tree.map(
            lambda a: NamedSharding(mesh, shd.logical_to_spec(a, mode, mesh)),
            axes_tree, is_leaf=_IS_AXES)
    return jax.tree.map(
        lambda a, s: NamedSharding(
            mesh, shd.logical_to_spec(a, mode, mesh, shape=s.shape)),
        axes_tree, sds_tree, is_leaf=_IS_AXES)


# ---------------------------------------------------------------------------
# Batch specs (train / prefill)
# ---------------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, n_nodes: Optional[int], batch: int,
                seq_len: int) -> Tuple[Dict[str, jax.ShapeDtypeStruct],
                                       Dict[str, tuple]]:
    """n_nodes None => serving layout (B, S); else (n, B/n, S)."""
    if n_nodes is None:
        lead, lead_axes = (batch,), ("batch",)
    else:
        assert batch % n_nodes == 0, (batch, n_nodes)
        lead, lead_axes = (n_nodes, batch // n_nodes), ("node",
                                                        "per_node_batch")
    shapes: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    if cfg.family == "encoder" and cfg.audio is not None:
        shapes["frames"] = _sds(lead + (seq_len, cfg.d_model), jnp.bfloat16)
        axes["frames"] = lead_axes + (None, None)
        shapes["mask"] = _sds(lead + (seq_len,), jnp.bool_)
        axes["mask"] = lead_axes + (None,)
        shapes["targets"] = _sds(lead + (seq_len,), jnp.int32)
        axes["targets"] = lead_axes + (None,)
        return shapes, axes
    shapes["inputs"] = _sds(lead + (seq_len,), jnp.int32)
    axes["inputs"] = lead_axes + (None,)
    shapes["targets"] = _sds(lead + (seq_len,), jnp.int32)
    axes["targets"] = lead_axes + (None,)
    if cfg.family == "encoder":
        shapes["mask"] = _sds(lead + (seq_len,), jnp.bool_)
        axes["mask"] = lead_axes + (None,)
    if cfg.family == "vlm" and cfg.vision is not None:
        n_img = cfg.vision.n_tiles * cfg.vision.patches_per_tile
        shapes["patches"] = _sds(lead + (n_img, cfg.d_model), jnp.bfloat16)
        axes["patches"] = lead_axes + (None, None)
    return shapes, axes


# ---------------------------------------------------------------------------
# Train specs
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TrainSpecs:
    state_sds: TrainState
    state_shardings: TrainState
    batch_sds: Dict[str, jax.ShapeDtypeStruct]
    batch_shardings: Dict[str, NamedSharding]
    lr_sds: jax.ShapeDtypeStruct
    lr_sharding: NamedSharding
    n_nodes: int
    mode: str


def train_specs(cfg: ModelConfig, mesh: Mesh, shape: InputShape, *,
                dist: DistConfig = DistConfig(),
                optimizer: OptimizerConfig = OptimizerConfig()) -> TrainSpecs:
    model = make_model(cfg)
    n_nodes = n_gossip_nodes(mesh, dist.node_axis)
    mode = "train_data" if dist.node_axis == "data" else "train_pod"
    opt = make_optimizer(optimizer, per_node=True)
    axes_box: Dict[str, Any] = {}
    from repro.core import algo as algo_lib

    def build_state(key):
        params, axes = model.init(key)
        axes_box["axes"] = axes
        stacked = stack_for_nodes(params, n_nodes)
        opt_state = opt.init(stacked)
        extras = algo_lib.init_extras(dist, stacked, n_nodes)
        return TrainState(params=stacked, opt_state=opt_state,
                          step=jnp.zeros((), jnp.int32), extras=extras)

    state_sds = jax.eval_shape(build_state, jax.random.PRNGKey(0))
    axes = axes_box["axes"]
    st_axes = stacked_axes(axes)
    state_axes_tree = state_axes(
        st_axes, optimizer.name,
        extras=algo_lib.extras_axes(dist, st_axes, axes))
    state_sh = _shardings(state_axes_tree, mode, mesh, state_sds)

    b_sds, b_axes = batch_specs(cfg, n_nodes, shape.global_batch,
                                shape.seq_len)
    b_sh = _shardings(b_axes, mode, mesh, b_sds)
    repl = NamedSharding(mesh, P())
    return TrainSpecs(state_sds=state_sds, state_shardings=state_sh,
                      batch_sds=b_sds, batch_shardings=b_sh,
                      lr_sds=_sds((), jnp.float32), lr_sharding=repl,
                      n_nodes=n_nodes, mode=mode)


# ---------------------------------------------------------------------------
# Serve specs (prefill / decode)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ServeSpecs:
    params_sds: PyTree
    params_shardings: PyTree
    batch_sds: Optional[Dict[str, jax.ShapeDtypeStruct]]   # prefill
    batch_shardings: Optional[Dict[str, NamedSharding]]
    cache_sds: Optional[PyTree]                             # decode
    cache_shardings: Optional[PyTree]
    tokens_sds: Optional[jax.ShapeDtypeStruct]
    tokens_sharding: Optional[NamedSharding]
    pos_sds: Optional[jax.ShapeDtypeStruct]
    pos_sharding: Optional[NamedSharding]
    mode: str


def serve_specs(cfg: ModelConfig, mesh: Mesh, shape: InputShape, *,
                param_sharding: str = "tp",
                context_parallel: Optional[bool] = None) -> ServeSpecs:
    model = make_model(cfg)
    axes_box: Dict[str, Any] = {}

    def build_params(key):
        params, axes = model.init(key)
        axes_box["axes"] = axes
        return params

    params_sds = jax.eval_shape(build_params, jax.random.PRNGKey(0))
    axes = axes_box["axes"]
    data_size = dict(mesh.shape).get("data", 1)
    if context_parallel is None:
        context_parallel = (shape.kind == "decode"
                            and shape.global_batch < data_size)
    mode = ("serve_cp" if context_parallel
            else {"tp": "serve_tp", "2d": "serve_2d",
                  "tp_seq": "serve_tp_seq"}[param_sharding])
    params_sh = _shardings(axes, mode, mesh, params_sds)

    if shape.kind == "prefill":
        b_sds, b_axes = batch_specs(cfg, None, shape.global_batch,
                                    shape.seq_len)
        b_sds.pop("targets", None)
        b_axes.pop("targets", None)
        b_sh = _shardings(b_axes, mode, mesh, b_sds)
        return ServeSpecs(params_sds, params_sh, b_sds, b_sh,
                          None, None, None, None, None, None, mode)

    # decode: full-length cache, one new token
    B = shape.global_batch
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(B, shape.seq_len))
    cache_axes = model.cache_axes()
    cache_sh = _shardings(cache_axes, mode, mesh, cache_sds)
    tok_axes = ("batch", None)
    pos_axes = ("batch",)
    return ServeSpecs(
        params_sds, params_sh, None, None, cache_sds, cache_sh,
        _sds((B, 1), jnp.int32),
        NamedSharding(mesh, shd.logical_to_spec(tok_axes, mode, mesh,
                                                shape=(B, 1))),
        _sds((B,), jnp.int32),
        NamedSharding(mesh, shd.logical_to_spec(pos_axes, mode, mesh,
                                                shape=(B,))),
        mode)
