"""Production mesh builders.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state.  Callers (dryrun.py) set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single CPU device.

The node-axis semantics (which mesh axes a gossip "node" spans under
``DistConfig.node_axis``) are canonical in ``repro.core.mixing`` —
``node_axis_names`` / ``node_shard_count`` — so the shard_map-aware comm
path and these launch helpers can never disagree.
"""
from __future__ import annotations

import jax

from repro.core.mixing import (model_axis_names,  # noqa: F401
                               model_shard_count, node_axis_names,
                               node_shard_count)  # re-exported for launchers


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def n_gossip_nodes(mesh: jax.sharding.Mesh, node_axis: str) -> int:
    """Gossip node count for a mesh under DistConfig.node_axis semantics
    (paper-faithful "data" flattens (pod, data); "pod" is hierarchical)."""
    return node_shard_count(mesh, node_axis)
