"""Production mesh builders.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state.  Callers (dryrun.py) set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def n_gossip_nodes(mesh: jax.sharding.Mesh, node_axis: str) -> int:
    """Gossip node count for a mesh under DistConfig.node_axis semantics."""
    axes = dict(mesh.shape)
    if node_axis == "data":
        # paper-faithful: nodes along data axis, flattened with pod if present
        return axes.get("data", 1) * axes.get("pod", 1)
    if node_axis == "pod":
        return axes.get("pod", 1)
    raise ValueError(node_axis)
