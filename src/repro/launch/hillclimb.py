import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import (see dryrun.py).

"""§Perf hillclimbing — three selected (arch × shape) pairs, iterated with
the hypothesis → change → re-lower → validate loop.  Results append to
results_hillclimb.jsonl; EXPERIMENTS.md §Perf narrates them.

Selected pairs (from the single-pod baseline sweep):
 1. gemma2-9b × decode_32k   — most collective-bound (coll 3.6 s dominates;
    kv_heads=8 doesn't divide model=16 ⇒ the 32k KV cache replicates
    per-chip and decode all-gathers it).
 2. jamba-1.5-large-398b × train_4k — worst absolute roofline (memory 15 s/
    step; mamba scan states + MoE dispatch in fp32).
 3. qwen3-moe-30b-a3b × train_4k — the paper-representative pair: gossip
    phase is MORE collective-expensive than the periodic All-Reduce
    (ring = 2 permutes of full fp32 params); the paper itself prescribes the
    one-peer exponential graph, and bf16 wire is the beyond-paper step.
"""
import argparse
import dataclasses
import json
from typing import Any, Dict

from repro.configs import INPUT_SHAPES, DistConfig, get_model_config
from repro.launch.dryrun import dryrun_serve, dryrun_train
from repro.launch.mesh import make_production_mesh

OUT = "results_hillclimb.jsonl"


def record(exp: str, variant: str, hypothesis: str, rec: Dict[str, Any],
           out_path: str) -> None:
    rec = dict(rec, experiment=exp, variant=variant, hypothesis=hypothesis)
    with open(out_path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    if "phases" in rec:
        rl = rec["phases"]["gossip"]["roofline"]
    else:
        rl = rec["roofline"]
    print(f"  >> {exp}/{variant}: dominant={rl['dominant']} "
          f"comp={rl['compute_s']:.3e} mem={rl['memory_s']:.3e} "
          f"coll={rl['collective_s']:.3e}", flush=True)


def exp1_gemma2_decode(mesh, out_path):
    """KV-cache sharding for GQA decode when kv_heads ∤ model axis."""
    cfg = get_model_config("gemma2-9b")
    shape = INPUT_SHAPES["decode_32k"]
    print("== exp1: gemma2-9b decode_32k ==", flush=True)
    rec = dryrun_serve(cfg, shape, mesh, param_sharding="2d")
    record("gemma2_decode_kv", "baseline_2d",
           "baseline: kv_heads=8 replicated on model=16 — every chip holds "
           "the full 32k KV; expect collective-bound", rec, out_path)
    rec = dryrun_serve(cfg, shape, mesh, param_sharding="tp_seq")
    record("gemma2_decode_kv", "kv_seq_over_model",
           "shard the cache SEQUENCE dim over model (flash-decoding style): "
           "per-chip KV drops 16x; decode reads 1/16 of the cache + a tiny "
           "partial-softmax all-reduce — predict collective term ↓ >10x and "
           "memory term ↓ ~10x", rec, out_path)
    rec = dryrun_serve(cfg, shape, mesh, param_sharding="tp_seq",
                       donate_cache=True)
    record("gemma2_decode_kv", "kv_seq+cache_donation",
           "remaining memory term ≈ a full cache copy: without input/output "
           "aliasing XLA materializes the updated cache — donate the cache "
           "buffer; predict memory term ↓ toward params+1/16-cache reads",
           rec, out_path)


def exp2_jamba_train(mesh, out_path):
    """Memory-bound hybrid training: scan dtype, remat policy, comm wire."""
    cfg = get_model_config("jamba-1.5-large-398b")
    shape = INPUT_SHAPES["train_4k"]
    print("== exp2: jamba-1.5-large-398b train_4k ==", flush=True)
    base_dist = DistConfig(algorithm="gossip_pga", topology="ring", H=6)
    rec = dryrun_train(cfg, shape, mesh, dist=base_dist)
    record("jamba_train", "baseline_ring_f32",
           "baseline: fp32 mamba scan states (B,S,di,N) dominate HLO bytes",
           rec, out_path)

    cfg_bf16 = dataclasses.replace(
        cfg, ssm=dataclasses.replace(cfg.ssm, scan_dtype="bfloat16"))
    rec = dryrun_train(cfg_bf16, shape, mesh, dist=base_dist)
    record("jamba_train", "scan_bf16",
           "mamba scan state fp32→bf16: scan-state traffic is ~half of the "
           "mamba layers' bytes — predict memory term ↓ ~25-35%",
           rec, out_path)

    dist_dots = dataclasses.replace(base_dist, remat_policy="dots")
    rec = dryrun_train(cfg_bf16, shape, mesh, dist=dist_dots)
    record("jamba_train", "scan_bf16+remat_dots",
           "checkpoint_dots policy keeps matmul outputs, recomputes the "
           "rest: fewer backward recompute reads — predict memory term ↓ "
           "but compute term ↑ slightly (re-lowered to verify direction)",
           rec, out_path)

    dist_comm = dataclasses.replace(base_dist, topology="one_peer_exp",
                                    comm_dtype="bfloat16")
    rec = dryrun_train(cfg_bf16, shape, mesh, dist=dist_comm)
    record("jamba_train", "scan_bf16+one_peer_bf16_comm",
           "gossip wire: ring(2 permutes, fp32) → one-peer-exp(1 permute, "
           "bf16) — predict gossip-phase collective bytes ↓ ~4x",
           rec, out_path)


def exp4_jamba_microbatch(mesh, out_path):
    """Follow-up on exp2: the memory term tracks live activations; gradient
    accumulation (4 microbatches) shrinks the per-pass working set 4x."""
    cfg = dataclasses.replace(
        get_model_config("jamba-1.5-large-398b"),
        ssm=dataclasses.replace(
            get_model_config("jamba-1.5-large-398b").ssm,
            scan_dtype="bfloat16"))
    shape = INPUT_SHAPES["train_4k"]
    print("== exp4: jamba train_4k + microbatching ==", flush=True)
    dist = DistConfig(algorithm="gossip_pga", topology="one_peer_exp", H=6,
                      comm_dtype="bfloat16")
    rec = dryrun_train(cfg, shape, mesh, dist=dist, microbatches=4)
    record("jamba_train", "scan_bf16+one_peer_bf16+microbatch4",
           "4-way grad accumulation: per-microbatch activations (incl. the "
           "(B,S,di,N) mamba scan states) shrink 4x — predict temp memory "
           "↓ ~3-4x; HLO bytes roughly unchanged (same total work), so the "
           "memory *term* holds while the footprint fits HBM", rec, out_path)


def exp3_qwen3moe_comm(mesh, out_path):
    """The paper's own knob: topology choice + wire dtype for gossip."""
    cfg = get_model_config("qwen3-moe-30b-a3b")
    shape = INPUT_SHAPES["train_4k"]
    print("== exp3: qwen3-moe-30b-a3b train_4k ==", flush=True)
    for variant, dist, hyp in [
        ("baseline_ring_f32",
         DistConfig(algorithm="gossip_pga", topology="ring", H=6),
         "baseline: ring gossip = 2 collective-permutes of the full fp32 "
         "param set per step"),
        ("one_peer_exp_f32",
         DistConfig(algorithm="gossip_pga", topology="one_peer_exp", H=6),
         "paper-faithful fix (§3, Assran et al.): one-peer exponential "
         "graph = ONE permute per step — predict gossip collective bytes "
         "↓ ~2x at equal convergence bound (C_β shrinks too)"),
        ("one_peer_exp_bf16",
         DistConfig(algorithm="gossip_pga", topology="one_peer_exp", H=6,
                    comm_dtype="bfloat16"),
         "beyond-paper: bf16 wire on the permute — predict another ~2x; "
         "the paper lists quantization as an orthogonal add-on"),
    ]:
        rec = dryrun_train(cfg, shape, mesh, dist=dist)
        record("qwen3moe_comm", variant, hyp, rec, out_path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default="all",
                    choices=["all", "exp1", "exp2", "exp3", "exp4"])
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=False)
    if args.exp in ("all", "exp1"):
        exp1_gemma2_decode(mesh, args.out)
    if args.exp in ("all", "exp2"):
        exp2_jamba_train(mesh, args.out)
    if args.exp in ("all", "exp3"):
        exp3_qwen3moe_comm(mesh, args.out)
    if args.exp in ("all", "exp4"):
        exp4_jamba_microbatch(mesh, args.out)


if __name__ == "__main__":
    main()
