import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Resume helper: the last exp2 variant + exp3 + exp4 (the earlier chain was
interrupted after exp2/scan_bf16+remat_dots)."""
import dataclasses

from repro.configs import INPUT_SHAPES, DistConfig, get_model_config
from repro.launch.dryrun import dryrun_train
from repro.launch.hillclimb import (OUT, exp3_qwen3moe_comm,
                                    exp4_jamba_microbatch, record)
from repro.launch.mesh import make_production_mesh


def main() -> None:
    mesh = make_production_mesh(multi_pod=False)
    cfg = get_model_config("jamba-1.5-large-398b")
    cfg_bf16 = dataclasses.replace(
        cfg, ssm=dataclasses.replace(cfg.ssm, scan_dtype="bfloat16"))
    shape = INPUT_SHAPES["train_4k"]
    dist_comm = DistConfig(algorithm="gossip_pga", topology="one_peer_exp",
                           H=6, comm_dtype="bfloat16")
    print("== exp2 (resume): jamba scan_bf16+one_peer_bf16_comm ==",
          flush=True)
    rec = dryrun_train(cfg_bf16, shape, mesh, dist=dist_comm)
    record("jamba_train", "scan_bf16+one_peer_bf16_comm",
           "gossip wire: ring(2 permutes, fp32) -> one-peer-exp(1 permute, "
           "bf16) — predict gossip-phase collective bytes ~4x down", rec, OUT)
    exp3_qwen3moe_comm(mesh, OUT)
    exp4_jamba_microbatch(mesh, OUT)


if __name__ == "__main__":
    main()
