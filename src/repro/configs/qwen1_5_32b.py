"""qwen1.5-32b [dense] — MHA (kv=40), QKV bias.

Source: Qwen1.5 family [hf:Qwen/Qwen1.5-0.5B card for the family recipe;
32B variant dims].  64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064,
head_dim=128, qkv bias.
"""
from repro.configs.base import ModelConfig

CITATION = "hf:Qwen/Qwen1.5-0.5B (Qwen1.5 family; 32B dims)"


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        family="dense",
        citation=CITATION,
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=40,
        head_dim=128,
        d_ff=27392,
        vocab_size=152_064,
        pattern=(("attn", "dense"),),
        qkv_bias=True,
        rope_theta=1_000_000.0,
    ).validate()


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b-reduced",
        family="dense",
        citation=CITATION,
        n_layers=2,
        d_model=320,
        n_heads=5,
        n_kv_heads=5,
        head_dim=64,
        d_ff=640,
        vocab_size=512,
        pattern=(("attn", "dense"),),
        qkv_bias=True,
    ).validate()
