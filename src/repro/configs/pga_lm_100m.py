"""pga-lm-100m — the end-to-end training-driver model (~100M params).

A GPT-style dense decoder sized near 100M parameters for the e2e example
(examples/train_lm.py): 12L d_model=768 12H d_ff=3072 vocab=32768, tied
embeddings -> ~110M params (85M non-embedding).
"""
from repro.configs.base import ModelConfig

CITATION = "framework driver config (GPT-2-small-like dims)"


def full_config() -> ModelConfig:
    return ModelConfig(
        name="pga-lm-100m",
        family="dense",
        citation=CITATION,
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=32_768,
        pattern=(("attn", "dense"),),
        tie_embeddings=True,
    ).validate()


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="pga-lm-reduced",
        family="dense",
        citation=CITATION,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        pattern=(("attn", "dense"),),
        tie_embeddings=True,
    ).validate()
