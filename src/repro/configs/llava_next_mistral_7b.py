"""llava-next-mistral-7b [vlm] — Mistral-7B backbone + anyres tiling stub.

Source: [hf:llava-hf/llava-v1.6-mistral-7b-hf].
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, head_dim=128.
The ViT/CLIP vision tower + mm projector is a STUB per the brief:
``input_specs`` provides pre-projected patch embeddings, anyres = base image
plus 4 tiles of 576 patches each (2880 image tokens).
"""
from repro.configs.base import ModelConfig, VisionStubConfig

CITATION = "hf:llava-hf/llava-v1.6-mistral-7b-hf"


def full_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        citation=CITATION,
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32_000,
        pattern=(("attn", "dense"),),
        rope_theta=1_000_000.0,
        vision=VisionStubConfig(n_tiles=5, patches_per_tile=576,
                                embed_dim=4096),
    ).validate()


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-reduced",
        family="vlm",
        citation=CITATION,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        pattern=(("attn", "dense"),),
        vision=VisionStubConfig(n_tiles=2, patches_per_tile=16, embed_dim=256),
    ).validate()
