"""Config registry: ``--arch <id>`` resolution for launchers and tests."""
from __future__ import annotations

import importlib
from typing import Callable, Dict

from repro.configs.base import (  # noqa: F401 (public re-exports)
    ALGORITHMS,
    INPUT_SHAPES,
    PUSH_SUM_ALGORITHMS,
    TOPOLOGIES,
    AudioStubConfig,
    DataConfig,
    DistConfig,
    InputShape,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    OptimizerConfig,
    SSMConfig,
    TrainConfig,
    VisionStubConfig,
)

# arch id -> module name. The 10 assigned architectures + paper workloads.
_ARCH_MODULES: Dict[str, str] = {
    "gemma2-9b": "gemma2_9b",
    "qwen3-0.6b": "qwen3_0_6b",
    "qwen2-0.5b": "qwen2_0_5b",
    "hubert-xlarge": "hubert_xlarge",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "xlstm-125m": "xlstm_125m",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "qwen1.5-32b": "qwen1_5_32b",
    # paper's own workloads / driver
    "bert-large": "bert_large",
    "pga-lm-100m": "pga_lm_100m",
}

ASSIGNED_ARCHS = tuple(list(_ARCH_MODULES)[:10])


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_model_config(arch: str, *, reduced: bool = False,
                     long_context: bool = False) -> ModelConfig:
    mod = _module(arch)
    if long_context and hasattr(mod, "long_context_config"):
        return mod.long_context_config()
    fn: Callable[[], ModelConfig] = (mod.reduced_config if reduced
                                     else mod.full_config)
    return fn()


def list_archs() -> tuple:
    return tuple(_ARCH_MODULES)
