"""qwen3-0.6b [dense] — qk-norm GQA.

Source: Qwen3 model family [hf:Qwen/Qwen3-8B family card; 0.6B variant].
28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936, head_dim=128, qk_norm.
"""
from repro.configs.base import ModelConfig

CITATION = "hf:Qwen/Qwen3-8B (Qwen3 family card; 0.6B variant)"


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b",
        family="dense",
        citation=CITATION,
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=3072,
        vocab_size=151_936,
        pattern=(("attn", "dense"),),
        qk_norm=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
    ).validate()


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b-reduced",
        family="dense",
        citation=CITATION,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        pattern=(("attn", "dense"),),
        qk_norm=True,
        tie_embeddings=True,
    ).validate()
