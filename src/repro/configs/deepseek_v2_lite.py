"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512), 2 shared + 64 routed top-6.

Source: DeepSeek-V2 [arXiv:2405.04434], DeepSeek-V2-Lite variant.
27L d_model=2048 16H d_ff=1408(expert) vocab=102400; first layer dense MLP
(d_ff=10944), remaining 26 layers MoE.  MLA: kv_lora_rank=512, per-head
nope_dim=128 + rope_dim=64, v_dim=128, no q compression in -Lite.

NOTE on the assignment line "MoE 64e top-6 — 2 shared+160 routed top-6": the
DeepSeek-V2-**Lite** card specifies 64 routed + 2 shared experts (160 routed is
the 236B DeepSeek-V2).  We follow the -Lite card (and the assignment's own
"64e top-6"), recorded in DESIGN.md §Arch-applicability.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CITATION = "arXiv:2405.04434 (DeepSeek-V2 / -Lite)"

DENSE_D_FF = 10944  # first-layer dense MLP width (model card)


def full_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        citation=CITATION,
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,        # MLA: all heads share the compressed latent
        head_dim=128,         # nope head dim (MLA config carries the split)
        d_ff=DENSE_D_FF,      # dense (first-layer) MLP width
        vocab_size=102_400,
        prefix_pattern=(("attn", "dense"),),
        pattern=(("attn", "moe"),),
        moe=MoEConfig(n_routed=64, top_k=6, d_ff_expert=1408, n_shared=2),
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=None,
                      rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    ).validate()


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-reduced",
        family="moe",
        citation=CITATION,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        prefix_pattern=(("attn", "dense"),),
        pattern=(("attn", "moe"),),
        moe=MoEConfig(n_routed=4, top_k=2, d_ff_expert=128, n_shared=1),
        mla=MLAConfig(kv_lora_rank=64, q_lora_rank=None,
                      rope_head_dim=16, nope_head_dim=32, v_head_dim=32),
    ).validate()
