"""hubert-xlarge [audio] — encoder-only transformer (wav2vec2 arch).

Source: HuBERT [arXiv:2106.07447].
48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504 (k-means codebook units).
The conv/mel frontend is a STUB per the brief: ``input_specs`` provides
pre-computed 20ms frame embeddings; training objective is masked prediction
over the 504-unit codebook.  Encoder-only => no decode shapes.
"""
from repro.configs.base import AudioStubConfig, ModelConfig

CITATION = "arXiv:2106.07447 (HuBERT)"


def full_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="encoder",
        citation=CITATION,
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        pattern=(("attn", "dense"),),
        causal=False,
        audio=AudioStubConfig(frame_dim=1280),
    ).validate()


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-reduced",
        family="encoder",
        citation=CITATION,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=504,
        pattern=(("attn", "dense"),),
        causal=False,
        audio=AudioStubConfig(frame_dim=256),
    ).validate()
