"""jamba-1.5-large-398b [hybrid] — Mamba + attention 1:7, MoE 16e top-2.

Source: Jamba [arXiv:2403.19887] / Jamba-1.5 [arXiv:2408.12570].
72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, head_dim=128.
Jamba block = 8 layers: attention at index 4, Mamba elsewhere; MoE replaces the
MLP on every other layer (odd indices), 16 experts top-2.

At 398B parameters this arch trains hierarchically (node_axis="pod"):
per-node parameter replicas at 16-way TP do not fit HBM; gossip runs across
pods over DCI while parameters are FSDP+TP sharded within the pod — exactly the
sparse-expensive-link regime the paper's PGA targets (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CITATION = "arXiv:2403.19887 (Jamba), arXiv:2408.12570 (Jamba-1.5)"

_JAMBA_BLOCK = (
    ("mamba", "dense"), ("mamba", "moe"),
    ("mamba", "dense"), ("mamba", "moe"),
    ("attn",  "dense"), ("mamba", "moe"),
    ("mamba", "dense"), ("mamba", "moe"),
)


def full_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        citation=CITATION,
        n_layers=72,                       # 9 Jamba blocks of 8
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65_536,
        pattern=_JAMBA_BLOCK,
        moe=MoEConfig(n_routed=16, top_k=2, d_ff_expert=24576, n_shared=0),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
        # 398B: fp32 replicas are pointless at this scale
        param_dtype="bfloat16",
    ).validate()


def long_context_config() -> ModelConfig:
    """jamba's attention layers are 1/8 of the stack; for long_500k decode the
    attention KV is the only S-proportional state. Runs as-is."""
    return full_config()


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-reduced",
        family="hybrid",
        citation=CITATION,
        n_layers=8,                        # one Jamba block, reduced widths
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        pattern=_JAMBA_BLOCK,
        moe=MoEConfig(n_routed=4, top_k=2, d_ff_expert=512, n_shared=0),
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2),
    ).validate()
