"""Typed configuration tree for the repro framework.

Everything in the system — model architecture, decentralized-training algorithm
(the paper's contribution), distribution/mesh layout, optimizer and data — is
driven from the dataclasses in this file.  One module per assigned architecture
lives next to this file; each exposes ``full_config()`` (the exact
published numbers, cited) and ``reduced_config()`` (a <=2-layer, d_model<=512,
<=4-expert variant of the same family for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block-pattern vocabulary
# ---------------------------------------------------------------------------
# A model is a (possibly empty) unscanned ``prefix_pattern`` followed by
# ``pattern`` repeated until ``n_layers`` is reached.  Each entry is
# (mixer, ffn):
#   mixer: "attn" | "attn_sw" (sliding window) | "mamba" | "mlstm" | "slstm"
#   ffn:   "dense" | "moe" | "none"
MIXERS = ("attn", "attn_sw", "mamba", "mlstm", "slstm")
FFNS = ("dense", "moe", "none")
BlockSpec = Tuple[str, str]


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int                    # routed experts
    top_k: int
    d_ff_expert: int
    n_shared: int = 0                # always-on shared experts (DeepSeek-V2)
    capacity_factor: float = 1.25    # dispatch capacity slack (drops beyond)
    aux_coef: float = 0.01           # load-balance auxiliary loss coefficient
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434)."""
    kv_lora_rank: int                # compressed KV latent dim (c_KV)
    q_lora_rank: Optional[int] = None  # None => full-rank Q projection
    rope_head_dim: int = 64          # decoupled RoPE key dim (d_h^R)
    nope_head_dim: int = 128         # non-RoPE per-head dim (d_h)
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """State-space / recurrent mixer parameters (Mamba + xLSTM)."""
    d_state: int = 16                # Mamba N (per-channel state)
    d_conv: int = 4                  # Mamba local conv width
    expand: int = 2                  # Mamba inner expansion d_inner = expand*d
    dt_rank: Optional[int] = None    # None => ceil(d_model/16)
    # xLSTM
    mlstm_head_dim: int = 128        # mLSTM matrix-memory head dim (qk dim)
    mlstm_expand: int = 2            # mLSTM up-projection factor
    slstm_heads: int = 4
    mlstm_chunk: int = 64            # chunkwise-parallel chunk length
                                     # (TPU tiling)
    scan_dtype: str = "float32"      # recurrence accumulation dtype
                                     # ("bfloat16" halves scan-state traffic)
    use_pallas_mlstm: bool = False   # TPU: repro.kernels.mlstm_chunk kernel


@dataclass(frozen=True)
class VisionStubConfig:
    """VLM frontend stub (anyres tiling).  The ViT itself is out of scope per
    the brief — ``input_specs`` supplies pre-computed patch embeddings."""
    n_tiles: int = 5                 # anyres: base image + 4 tiles (llava-1.6)
    patches_per_tile: int = 576      # 24x24 for CLIP-ViT-L/14 @336px
    embed_dim: int = 4096            # after the (stubbed) mm projector


@dataclass(frozen=True)
class AudioStubConfig:
    """Audio frontend stub (conv feature extractor).  ``input_specs`` supplies
    20ms-frame embeddings directly."""
    frame_dim: int = 1280
    mask_prob: float = 0.08          # HuBERT masked-prediction span starts
    mask_span: int = 10


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|encoder|moe|vlm|ssm|hybrid
    citation: str                    # source paper / model card
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # None => d_model // n_heads
    pattern: Tuple[BlockSpec, ...] = (("attn", "dense"),)
    prefix_pattern: Tuple[BlockSpec, ...] = ()
    causal: bool = True
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    qk_norm: bool = False            # Qwen3: RMSNorm on per-head q,k
    qkv_bias: bool = False           # Qwen1.5/Qwen2
    attn_logit_softcap: Optional[float] = None   # Gemma2: 50.0
    final_logit_softcap: Optional[float] = None  # Gemma2: 30.0
    sliding_window: Optional[int] = None         # for "attn_sw" layers
    post_block_norm: bool = False    # Gemma2 post-norms
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    vision: Optional[VisionStubConfig] = None
    audio: Optional[AudioStubConfig] = None
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"

    # ---- derived -----------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return (self.head_dim if self.head_dim is not None
                else self.d_model // self.n_heads)

    @property
    def layers(self) -> Tuple[BlockSpec, ...]:
        """Fully unrolled per-layer (mixer, ffn) list."""
        body = self.n_layers - len(self.prefix_pattern)
        if body < 0 or (len(self.pattern) and body % len(self.pattern) != 0):
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} incompatible with "
                f"prefix={len(self.prefix_pattern)} "
                f"pattern={len(self.pattern)}")
        reps = body // len(self.pattern)
        return self.prefix_pattern + self.pattern * reps

    @property
    def n_scan_blocks(self) -> int:
        return (self.n_layers - len(self.prefix_pattern)) // len(self.pattern)

    def validate(self) -> "ModelConfig":
        for mixer, ffn in self.layers:
            if mixer not in MIXERS:
                raise ValueError(f"unknown mixer {mixer!r}")
            if ffn not in FFNS:
                raise ValueError(f"unknown ffn {ffn!r}")
            if ffn == "moe" and self.moe is None:
                raise ValueError("moe block requires MoEConfig")
            if mixer in ("mamba", "mlstm", "slstm") and self.ssm is None:
                raise ValueError(f"{mixer} block requires SSMConfig")
        if self.n_heads % self.n_kv_heads != 0:
            raise ValueError("n_heads must be divisible by n_kv_heads")
        return self

    def has_mixer(self, *kinds: str) -> bool:
        return any(m in kinds for m, _ in self.layers)

    @property
    def supports_long_context(self) -> bool:
        """True if decode with a 500k context is sub-quadratic / bounded-state
        for every layer (SSM/hybrid) or all attention is sliding-window."""
        for mixer, _ in self.layers:
            if mixer == "attn":
                return False
        return True

    @property
    def is_encoder(self) -> bool:
        return not self.causal


# ---------------------------------------------------------------------------
# Distribution / decentralized-training config (the paper's knobs)
# ---------------------------------------------------------------------------
# ALGORITHMS / PUSH_SUM_ALGORITHMS are sourced from the repro.core.algo
# registry — the single place an algorithm is declared — but lazily (module
# __getattr__ below): importing them at module scope would cycle through
# repro.core back into this file, and configs must stay dependency-light.
TOPOLOGIES = ("ring", "grid", "exp", "one_peer_exp", "full", "disconnected",
              "directed_ring", "directed_exp")


def _algorithm_names() -> Tuple[str, ...]:
    from repro.core.algo import algorithm_names
    return algorithm_names()


def _push_sum_algorithm_names() -> Tuple[str, ...]:
    # push-sum works with any algorithm whose rounds are gossip and/or
    # global averaging — slowmo/hier_pga compose outer-iterate or pod
    # rounds that have no de-biased push-sum form yet (DESIGN.md §2.5),
    # and gt_pga's tracker recursion assumes row-stochastic mixing
    from repro.core.algo import push_sum_algorithm_names
    return push_sum_algorithm_names()


def __getattr__(name: str):
    # PEP 562: resolve the registry-backed tuples on first access and
    # cache them as real module attributes
    if name == "ALGORITHMS":
        value = _algorithm_names()
    elif name == "PUSH_SUM_ALGORITHMS":
        value = _push_sum_algorithm_names()
    else:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    globals()[name] = value
    return value


@dataclass(frozen=True)
class DistConfig:
    algorithm: str = "gossip_pga"
    topology: str = "one_peer_exp"   # paper's deep-learning default
                                     # (Assran et al.)
    H: int = 6                       # global averaging period
                                     # (paper's ImageNet/BERT value)
    node_axis: str = "data"          # "data": nodes along data axis
                                     # (paper-faithful); "pod":
                                     # hierarchical — nodes are pods,
                                     # FSDP within
    # SlowMo (Wang et al. 2019) — Gossip-PGA == SlowMo(beta=0, alpha=1)
    slowmo_beta: float = 0.0
    slowmo_lr: float = 1.0
    # Hier-PGA (beyond-paper): intra-pod averaging period (global = H)
    hier_h_pod: int = 3
    n_pods: int = 2
    # Gossip-AGA (paper Alg. 2)
    aga_h_init: int = 4
    aga_warmup: int = 64             # K_w warmup iterations for
                                     # F_init running avg
    aga_h_max: int = 64              # Corollary 1 requires bounded H
    # Mesh / sharding
    data_axis: str = "data"
    model_axis: str = "model"        # tensor-parallel mesh axis; when the
                                     # mesh carries it, the sharded comm
                                     # path runs 2-D (node, model): the
                                     # packed state's columns are sliced
                                     # over it (DESIGN.md §2.1)
    pod_axis: str = "pod"
    comm_dtype: str = "float32"      # gossip/all-reduce wire dtype
                                     # ("bfloat16" halves collective bytes —
                                     # the paper's "orthogonal quantization")
    comm_backend: str = "reference"  # "reference": roll/jnp.mean mixing
                                     # "pallas": fused single-pass kernels
                                     #           (repro.kernels.mixing_pallas)
    comm_compression: str = "none"   # wire compressor (DESIGN.md §2.3):
                                     # none | identity | int8 | fp8 | topk
                                     # | randk (repro.compress registry);
                                     # identity routes to the exact
                                     # uncompressed path bit-identically
    comm_compression_k: int = 32     # elements kept per node per leaf for
                                     # the topk/randk sparsifiers (clipped
                                     # to leaf size)
    comm_global_compression: str = "none"
                                     # compressed collective for the
                                     # global/pod-averaging phases
                                     # (DESIGN.md §2.3 "Compressed
                                     # collectives"): none | identity |
                                     # int8 | fp8.  Quantizers only —
                                     # sparsifier payloads cannot ride a
                                     # reduce-scatter.  identity routes to
                                     # the exact psum path bit-identically;
                                     # a lossy choice supersedes
                                     # comm_compression/comm_dtype for
                                     # those phases (gossip rounds keep
                                     # their own compressor)
    comm_error_feedback: bool = False
                                     # per-node EF residual memory
                                     # (TrainState.ef_state): compression
                                     # error is fed back next round, not
                                     # dropped
    comm_shard_mode: str = "auto"    # pallas backend under a mesh-sharded
                                     # node axis (DESIGN.md §2.1):
                                     # "auto": per-shard kernels + ppermute
                                     #         halo when the node axis spans
                                     #         >1 device, stacked otherwise
                                     # "stacked": always the local kernels
                                     # "sharded": require a sharded mesh
    pallas_leaf_threshold: int = 262_144
                                     # per-node elements at which a leaf gets
                                     # its own kernel dispatch instead of the
                                     # concat staging buffer
    push_sum: bool = False           # push-sum gossip (DESIGN.md §2.5):
                                     # column-stochastic directed mixing +
                                     # per-node weight scalar
                                     # (TrainState.push_weight), de-biased
                                     # reads x/w.  Required for the
                                     # directed topologies and for fault
                                     # injection (core.faults)
    comm_overlap: bool = False       # pipelined gossip (DESIGN.md §2.6):
                                     # the exchange of step t overlaps the
                                     # compute of step t+1 (one-step-stale
                                     # double-buffered wire state via
                                     # mixing.start_round/finish_round);
                                     # global/pod_avg rounds stay
                                     # synchronous and flush the buffer
    remat: str = "block"             # "none" | "block":
                                     # jax.checkpoint each scanned
                                     # block
    remat_policy: str = "nothing"    # "nothing" | "dots"
                                     # (checkpoint_dots) — perf knob
    serve_param_sharding: str = "tp" # "tp" (model axis) | "2d"
                                     # (data+model, big archs)
    fsdp: bool = False               # shard params over data axis
                                     # too (node_axis="pod")

    def validate(self) -> "DistConfig":
        # registry lookups via the lazy helpers — bare names inside a
        # function body do NOT trigger the module __getattr__
        if self.algorithm not in _algorithm_names():
            raise ValueError(
                f"DistConfig.validate: unknown algorithm "
                f"{self.algorithm!r} (expected one of "
                f"{_algorithm_names()})")
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"unknown topology {self.topology!r}")
        if self.H < 1:
            raise ValueError("H must be >= 1")
        if self.node_axis not in ("data", "pod"):
            raise ValueError("node_axis must be 'data' or 'pod'")
        if (not self.model_axis
                or self.model_axis in (self.data_axis, self.pod_axis)):
            raise ValueError(
                f"model_axis must be a mesh axis name distinct from "
                f"data_axis={self.data_axis!r} and "
                f"pod_axis={self.pod_axis!r} (got {self.model_axis!r}) — "
                f"the 2-D comm path slices packed columns over it")
        if self.comm_backend not in ("reference", "pallas"):
            raise ValueError("comm_backend must be 'reference' or 'pallas'")
        # kept in sync with repro.compress.COMPRESSORS (test_compress.py
        # pins the two tuples equal; no import here — configs must stay
        # dependency-light)
        if self.comm_compression not in ("none", "identity", "int8", "fp8",
                                         "topk", "randk"):
            raise ValueError(
                f"unknown comm_compression {self.comm_compression!r} "
                "(expected none|identity|int8|fp8|topk|randk)")
        if self.comm_compression_k < 1:
            raise ValueError("comm_compression_k must be >= 1")
        # kept in sync with repro.compress.COLLECTIVE_COMPRESSORS
        # (test_compress.py pins the tuples equal)
        if self.comm_global_compression not in ("none", "identity", "int8",
                                                "fp8"):
            raise ValueError(
                f"unknown comm_global_compression "
                f"{self.comm_global_compression!r} (expected "
                "none|identity|int8|fp8 — sparsifiers cannot ride the "
                "reduce-scatter collective)")
        if self.comm_error_feedback and self.comm_compression in (
                "none", "identity") and self.comm_global_compression in (
                "none", "identity"):
            raise ValueError("comm_error_feedback requires a lossy "
                             "comm_compression (int8|fp8|topk|randk) or "
                             "comm_global_compression (int8|fp8)")
        if self.n_pods < 1:
            raise ValueError("n_pods must be >= 1")
        if self.comm_shard_mode not in ("auto", "stacked", "sharded"):
            raise ValueError("comm_shard_mode must be 'auto', 'stacked', "
                             "or 'sharded'")
        if self.pallas_leaf_threshold < 1:
            raise ValueError("pallas_leaf_threshold must be >= 1")
        if self.topology in ("directed_ring", "directed_exp") \
                and not self.push_sum:
            raise ValueError(
                f"topology {self.topology!r} is directed (column-"
                f"stochastic): it requires push_sum=True so reads are "
                f"de-biased by the weight scalar (DESIGN.md §2.5)")
        if self.push_sum:
            push_ok = _push_sum_algorithm_names()
            if self.algorithm not in push_ok:
                raise ValueError(
                    f"push_sum composes with algorithms "
                    f"{push_ok}, not {self.algorithm!r}")
            if self.topology == "grid":
                raise ValueError(
                    "push_sum has no 2-D grid decomposition — use a 1-D "
                    "(directed) circulant topology")
            if self.comm_global_compression != "none":
                raise ValueError(
                    "push_sum global rounds average the (x, w) pair over "
                    "the active set and cannot ride the compressed "
                    "collective — set comm_global_compression='none'")
            if self.comm_overlap:
                raise ValueError(
                    "comm_overlap does not compose with push_sum: the "
                    "de-biased read x/w needs x and w mixed by the *same* "
                    "round, but the overlapped correction applies a stale "
                    "buffer to a fresh iterate (DESIGN.md §2.6)")
        return self

    def comm_spec(self, n_nodes: int, mesh=None):
        """Canonical :class:`repro.core.mixing.CommSpec` constructor — the
        single place the config's comm knobs become the round-invariant
        spec every ``communicate``/``start_round``/``finish_round`` call
        threads (imports stay lazy: configs are dependency-light)."""
        import jax.numpy as jnp
        from repro.compress import make_compressor
        from repro.core.mixing import CommSpec
        return CommSpec(
            topology=self.topology,
            n_nodes=n_nodes,
            n_pods=self.n_pods,
            backend=self.comm_backend,
            mesh=mesh,
            node_axis=self.node_axis,
            model_axis=self.model_axis,
            shard_mode=self.comm_shard_mode,
            leaf_threshold=self.pallas_leaf_threshold,
            comm_dtype=jnp.bfloat16 if self.comm_dtype == "bfloat16"
            else None,
            compressor=make_compressor(self.comm_compression,
                                       k=self.comm_compression_k),
            global_compressor=make_compressor(
                self.comm_global_compression)).validate()

    def validate_nodes(self, n_nodes: int) -> "DistConfig":
        """Checks that need the runtime node count: any algorithm that runs
        a ``pod_avg`` round requires ``n_pods`` to divide ``n_nodes`` —
        caught here with a clear error instead of surfacing later as
        mis-shaped pod blocks/halos in the mixing layer."""
        if self.algorithm == "hier_pga" and n_nodes % self.n_pods:
            raise ValueError(
                f"DistConfig: n_pods={self.n_pods} does not divide "
                f"n_nodes={n_nodes} — hier_pga's pod_avg round needs equal "
                f"pod blocks")
        return self


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "sgd"                # sgd | adamw | lamb
    lr: float = 0.1
    momentum: float = 0.9
    nesterov: bool = True            # paper's ImageNet recipe
    weight_decay: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    grad_clip: Optional[float] = 1.0
    schedule: str = "warmup_cosine"  # constant | warmup_cosine |
                                     # warmup_poly | step
    warmup_steps: int = 100
    decay_steps: Tuple[int, ...] = ()   # for "step" schedule (paper:
                                        # 30/60/90 epochs)
    decay_factor: float = 0.1
    total_steps: int = 1000
    min_lr_ratio: float = 0.0


@dataclass(frozen=True)
class DataConfig:
    kind: str = "synthetic_lm"       # synthetic_lm | logistic
    non_iid: bool = True             # per-node distribution shift (paper §5.1)
    non_iid_alpha: float = 0.5       # strength of per-node shift
    seed: int = 0


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    dist: DistConfig = field(default_factory=DistConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    data: DataConfig = field(default_factory=DataConfig)
    global_batch: int = 256
    seq_len: int = 4096
    microbatches: int = 1            # grad-accumulation splits per node-batch
    steps: int = 200
    log_every: int = 10
    ckpt_every: int = 0              # 0 = disabled
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    z_loss: float = 0.0

    def replace(self, **kw) -> "TrainConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Assigned input shapes (public pool)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",  524_288,    1, "decode"),
}
