"""gemma2-9b [dense] — local+global alternating attention, logit softcaps.

Source: Gemma 2 technical report [arXiv:2408.00118].
42L d_model=3584 16H (GQA kv=8, head_dim=256) d_ff=14336 vocab=256000,
sliding window 4096 on every other layer, attn softcap 50, final softcap 30.
"""
from repro.configs.base import ModelConfig

CITATION = "arXiv:2408.00118 (Gemma 2)"


def full_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        citation=CITATION,
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256_000,
        pattern=(("attn_sw", "dense"), ("attn", "dense")),
        sliding_window=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        post_block_norm=True,
        tie_embeddings=True,
        rope_theta=10_000.0,
    ).validate()


def long_context_config() -> ModelConfig:
    """500k-decode variant: global-attention layers switched to sliding-window
    (documented deviation in DESIGN.md §Arch-applicability) so the KV working
    set is bounded — the dense-arch carve-out the brief allows."""
    cfg = full_config()
    import dataclasses
    return dataclasses.replace(
        cfg, name="gemma2-9b-sw", pattern=(("attn_sw", "dense"),)).validate()


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b-reduced",
        family="dense",
        citation=CITATION,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        pattern=(("attn_sw", "dense"), ("attn", "dense")),
        sliding_window=64,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        post_block_norm=True,
        tie_embeddings=True,
    ).validate()
