"""qwen3-moe-30b-a3b [moe] — 128 routed experts, top-8, GQA kv=4.

Source: [hf:Qwen/Qwen3-30B-A3B].
48L d_model=2048 32H (GQA kv=4) expert d_ff=768 vocab=151936, head_dim=128,
qk_norm (Qwen3 family), every layer MoE, no shared experts.
"""
from repro.configs.base import ModelConfig, MoEConfig

CITATION = "hf:Qwen/Qwen3-30B-A3B"


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        citation=CITATION,
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,             # unused by moe blocks; kept = expert width
        vocab_size=151_936,
        pattern=(("attn", "moe"),),
        qk_norm=True,
        rope_theta=1_000_000.0,
        moe=MoEConfig(n_routed=128, top_k=8, d_ff_expert=768, n_shared=0),
    ).validate()


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-reduced",
        family="moe",
        citation=CITATION,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=128,
        vocab_size=512,
        pattern=(("attn", "moe"),),
        qk_norm=True,
        moe=MoEConfig(n_routed=4, top_k=2, d_ff_expert=128, n_shared=0),
    ).validate()
