"""xlstm-125m [ssm] — sLSTM + mLSTM blocks.

Source: xLSTM [arXiv:2405.04517].
12L d_model=768 4H (slstm heads) d_ff=0 (projections live inside the blocks)
vocab=50304.  Block ratio mLSTM:sLSTM = 5:1 (xLSTM[7:1]-style sparse sLSTM
placement adapted to 12 layers; sLSTM at layers 5 and 11 — recorded choice).
mLSTM is implemented chunkwise-parallel (TPU/MXU-native); sLSTM is a scalar
recurrence via lax.scan (inherently sequential — see DESIGN.md hardware notes).
"""
from repro.configs.base import ModelConfig, SSMConfig

CITATION = "arXiv:2405.04517 (xLSTM)"


def full_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        citation=CITATION,
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        head_dim=192,
        d_ff=0,
        vocab_size=50_304,
        pattern=(("mlstm", "none"),) * 5 + (("slstm", "none"),),
        ssm=SSMConfig(mlstm_head_dim=96, mlstm_expand=2, slstm_heads=4,
                      mlstm_chunk=64),
        tie_embeddings=True,
    ).validate()


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-reduced",
        family="ssm",
        citation=CITATION,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=0,
        vocab_size=512,
        pattern=(("mlstm", "none"), ("slstm", "none")),
        ssm=SSMConfig(mlstm_head_dim=32, mlstm_expand=2, slstm_heads=4,
                      mlstm_chunk=16),
        tie_embeddings=True,
    ).validate()
