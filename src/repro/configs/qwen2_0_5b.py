"""qwen2-0.5b [dense] — GQA (kv=2), QKV bias.

Source: Qwen2 technical report [arXiv:2407.10671].
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936, head_dim=64, qkv bias.
"""
from repro.configs.base import ModelConfig

CITATION = "arXiv:2407.10671 (Qwen2)"


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b",
        family="dense",
        citation=CITATION,
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab_size=151_936,
        pattern=(("attn", "dense"),),
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
    ).validate()


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b-reduced",
        family="dense",
        citation=CITATION,
        n_layers=2,
        d_model=224,
        n_heads=7,
        n_kv_heads=1,
        head_dim=32,
        d_ff=448,
        vocab_size=512,
        pattern=(("attn", "dense"),),
        qkv_bias=True,
        tie_embeddings=True,
    ).validate()
