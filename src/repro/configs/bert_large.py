"""bert-large — the paper's own language-modeling workload (§5.3).

Source: BERT [arXiv:1810.04805]; the paper trains BERT-Large (~330M) phase 1
with LAMB.  Encoder-only, masked-LM objective (same masked-prediction path as
the hubert family in this framework).
"""
from repro.configs.base import ModelConfig

CITATION = "arXiv:1810.04805 (BERT); paper §5.3 workload"


def full_config() -> ModelConfig:
    return ModelConfig(
        name="bert-large",
        family="encoder",
        citation=CITATION,
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=30_522,
        pattern=(("attn", "dense"),),
        causal=False,
    ).validate()


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="bert-large-reduced",
        family="encoder",
        citation=CITATION,
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        pattern=(("attn", "dense"),),
        causal=False,
    ).validate()
